"""Continuous batching vs the lock-step barrier, under a rack outage —
narrated.

A 64-node depth-3 cluster serves a seeded open-loop traffic stream
(Poisson arrivals, three SLO classes, a burst window) while a rack dies
mid-campaign. The same pre-generated arrival schedule runs twice:

  * **continuous** — per-legion in-flight windows admit a new micro-batch
    the moment a slot frees; requests advance prefill-then-decode one tick
    at a time; a request that dies mid-decode migrates its decode progress
    to a survivor instead of restarting from prefill;
  * **lockstep** — the pre-continuous baseline: one batch per node per
    round, and the round's simulated duration stretches to the slowest
    in-flight batch (the barrier everyone waits on).

Prints the ledger (exactly-once accounting including parked/shed), the
migration counters, and the p99 latency of both modes in simulated-clock
seconds.

  PYTHONPATH=src python examples/continuous_serving.py

Exits nonzero if the exactly-once ledger breaks, a healthy legion
starves, or continuous batching fails to beat the barrier at p99 — CI
runs this as the serving smoke test (``make serve-demo``).
"""
import sys

from repro.core import FaultInjector, LegioPolicy, VirtualCluster
from repro.serve import (
    Burst,
    Request,
    ServeEngine,
    TrafficGenerator,
    recovery_preset,
)

N_NODES = 64
T_END = 16.0                       # arrival window, simulated seconds
RATE = 24.0                        # arrivals per simulated second
FAULTS = [(4, 8), (4, 9), (4, 10)]    # one rack's worth, mid-campaign


def work(node: int, batch: list[Request], step: int) -> dict[int, int]:
    return {r.rid: r.rid for r in batch}


def schedule() -> list[tuple[float, object]]:
    gen = TrafficGenerator(RATE, seed=7, bursts=(Burst(5.0, 8.0, 2.5),))
    out = []
    t = 0.0
    while t < T_END:
        out.extend((t + 1.0, a) for a in gen.arrivals(t, t + 1.0))
        t += 1.0
    return out


def run(mode: str, sched: list[tuple[float, object]]) -> dict:
    policy = LegioPolicy(legion_size=4, serve_microbatch=2, serve_window=2,
                         **recovery_preset("nonblocking"))
    cluster = VirtualCluster(N_NODES, policy=policy,
                             injector=FaultInjector.at(FAULTS))
    engine = ServeEngine(cluster, work, continuous=(mode == "continuous"))
    i, rounds = 0, 0
    while rounds < 300:
        now = cluster.clock.sim_seconds
        while i < len(sched) and sched[i][0] <= now:
            j = i
            while j < len(sched) and sched[j][0] <= now:
                j += 1
            engine.submit([a for _, a in sched[i:j]])
            i = j
        if i >= len(sched) and not engine.pending:
            break
        engine.run_round()
        rounds += 1
    m = engine.metrics.summary(max(rounds, 1))
    m["mode"] = mode
    m["submitted"] = len(sched)
    m["unserved"] = engine.pending
    m["rounds"] = rounds
    m["sim_seconds"] = cluster.clock.sim_seconds
    m["unique"] = (len(set(engine.completed)) == len(engine.completed)
                   and len(engine.metrics.completions)
                   == len(engine.completed))
    return m


def main() -> int:
    sched = schedule()
    print(f"continuous serving demo: n={N_NODES}, {len(sched)} requests "
          f"over {T_END:.0f} sim-seconds, rack of "
          f"{len(FAULTS)} dies at step {FAULTS[0][0]}\n")
    results = {}
    ok = True
    for mode in ("continuous", "lockstep"):
        m = run(mode, sched)
        results[mode] = m
        accounted = (m["completed"] + m["parked"] + m["abandoned"]
                     + m["shed"] + m["unserved"])
        conserved = accounted == m["submitted"] and m["unserved"] == 0
        print(f"== {mode} ==")
        print(f"   rounds {m['rounds']:3d} spanning "
              f"{m['sim_seconds']:.0f} sim-seconds")
        print(f"   ledger: {m['completed']} completed, {m['parked']} parked, "
              f"{m['abandoned']} abandoned, {m['shed']} shed, "
              f"{m['unserved']} unserved "
              f"{'[conserved]' if conserved else '[BROKEN]'}")
        print(f"   redelivery: {m['requeues']} requeues, "
              f"{m['duplicates_suppressed']} duplicates suppressed, "
              f"{m['migrations']} decode migrations "
              f"({m['decode_ticks_preserved']} ticks preserved)")
        print(f"   phases: {m['prefill_ticks']} prefill ticks, "
              f"{m['decode_ticks']} decode ticks")
        print(f"   latency: p50 {m['p50_latency_sim']:.1f}s, "
              f"p99 {m['p99_latency_sim']:.1f}s, "
              f"p999 {m['p999_latency_sim']:.1f}s (sim); "
              f"starved rounds {m['starved_rounds']}\n")
        ok &= conserved and m["unique"] and m["starved_rounds"] == 0
    cont, lock = results["continuous"], results["lockstep"]
    beat = cont["p99_latency_sim"] < lock["p99_latency_sim"]
    ok &= beat and cont["migrations"] > 0
    print(f"p99: continuous {cont['p99_latency_sim']:.1f}s vs lockstep "
          f"{lock['p99_latency_sim']:.1f}s "
          f"{'[continuous wins]' if beat else '[BARRIER WON]'}")
    print("continuous serving demo:", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
