"""Quickstart: fault-resilient execution in ~40 lines.

An embarrassingly parallel job (estimate pi by Monte Carlo) runs on a
16-node virtual cluster. Two nodes die mid-run — including a legion master.
The application code below never mentions faults: the LegioExecutor detects,
agrees, repairs, and the estimate converges anyway (on fewer samples —
the paper's "approximate result" trade-off).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import FaultInjector, LegioExecutor, LegioPolicy, VirtualCluster

SAMPLES_PER_SHARD = 100_000


def throw_darts(node: int, shard: int, step: int) -> np.ndarray:
    """[hits, throws] for one shard — pure function of (shard, step)."""
    rng = np.random.default_rng(shard * 1_000_003 + step)
    xy = rng.uniform(-1, 1, (SAMPLES_PER_SHARD, 2))
    hits = np.sum(np.sum(xy * xy, axis=1) <= 1.0)
    return np.array([hits, SAMPLES_PER_SHARD], dtype=np.float64)


def main() -> None:
    cluster = VirtualCluster(
        16,
        policy=LegioPolicy(legion_size=4),
        injector=FaultInjector.at([(3, 9), (6, 4)]),   # node 4 is a master
    )
    executor = LegioExecutor(cluster, throw_darts)

    hits = throws = 0.0
    for step in range(10):
        report = executor.run_step()
        hits += report.reduced[0]
        throws += report.reduced[1]
        status = ""
        if report.repair:
            role = "MASTER" if report.repair.master_failed else "worker"
            status = (f"  <- repaired {role} failure of node "
                      f"{report.failed_now}, {report.repair.survivors} survive")
        print(f"step {step}: pi ~= {4 * hits / throws:.5f} "
              f"({int(throws):,} samples){status}")

    err = abs(4 * hits / throws - np.pi)
    print(f"\nfinal: pi ~= {4 * hits / throws:.5f} (|err| = {err:.2e}) "
          f"with {len(cluster.live_nodes)}/16 nodes surviving")
    assert err < 5e-3


if __name__ == "__main__":
    main()
