"""Virtual-screening fleet: the paper's §VI docking scenario, end to end.

A database of "molecules" is scored against a target by a fleet of nodes
running batched model inference (the screening surrogate is a real model
forward pass — scores are logits energies). Nodes fail mid-screen; Legio
discards them, re-queues their in-flight work (REBALANCE) and the screen
completes with the full database scored — or, with --drop, with exactly the
dead nodes' slices missing (the paper's DROP trade-off).

  PYTHONPATH=src python examples/fleet_screening.py
  PYTHONPATH=src python examples/fleet_screening.py --drop
"""
import argparse
import time

import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core import FaultInjector, LegioPolicy, VirtualCluster
from repro.launch.serve import ResilientServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--drop", action="store_true",
                    help="abandon failed nodes' requests (paper DROP)")
    ap.add_argument("--molecules", type=int, default=96)
    ap.add_argument("--nodes", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3.2-3b")
    cluster = VirtualCluster(
        args.nodes, policy=LegioPolicy(legion_size=4),
        injector=FaultInjector.at([(1, 2), (2, 6)]))
    server = ResilientServer(
        cfg, cluster, prompt_len=24, decode_tokens=4, batch_per_node=4,
        requeue=not args.drop)

    print(f"[screen] {args.molecules} molecules over {args.nodes} nodes "
          f"({'DROP' if args.drop else 'REBALANCE'} policy), "
          f"2 failures scheduled")
    t0 = time.perf_counter()
    rep = server.run(args.molecules)
    dt = time.perf_counter() - t0

    # "docking scores": mean logit energy of each molecule's generated tokens
    scores = {rid: float(np.mean(tokens)) for rid, tokens in
              server.completed.items()}
    top = sorted(scores.items(), key=lambda kv: kv[1])[:5]
    print(f"[screen] {rep['completed']} scored, {rep['abandoned']} abandoned, "
          f"{rep['survivors']}/{args.nodes} nodes survive, "
          f"{rep['repairs']} repairs, {dt:.1f}s")
    print("[screen] top-5 candidates:", [rid for rid, _ in top])

    if args.drop:
        assert rep["completed"] + rep["abandoned"] == args.molecules
        print("[screen] DROP: result is a valid screen of the surviving slices")
    else:
        assert rep["completed"] == args.molecules
        print("[screen] REBALANCE: full database screened despite failures")


if __name__ == "__main__":
    main()
