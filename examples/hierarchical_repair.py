"""Anatomy of a hierarchical repair (paper §V, Fig. 3) — narrated.

Builds the paper's exact topology figure (16 processes, k=4), kills a
master, and prints every repair stage with its communicator, participants,
and S(x) model cost — then compares against the flat shrink and sweeps the
cluster size to show the crossover the paper derives in Eq. 2.

  PYTHONPATH=src python examples/hierarchical_repair.py
"""
from repro.core import LegioPolicy, ShrinkCostModel, ShrinkEngine
from repro.core.hierarchy import LegionTopology
from repro.core.policy import optimal_k_linear


def main() -> None:
    topo = LegionTopology.build(list(range(16)), 4)
    print("topology: 16 nodes, k=4")
    for lg in topo.legions:
        print(f"  legion {lg.index}: members {lg.members} "
              f"(master {lg.master}, POV {topo.pov(lg.index)})")

    eng = ShrinkEngine(LegioPolicy(), ShrinkCostModel(p=1.0))
    victim = topo.legions[1].master
    print(f"\nkilling node {victim} — master of legion 1. Repair plan:")
    report = eng.repair(topo, {victim})
    for i, step in enumerate(report.steps):
        print(f"  {i + 1}. {step.op:8s} on {step.comm:9s} "
              f"participants={list(step.participants)} "
              f"S(x) cost={step.cost_units:.4f}s")
    print(f"total model cost {report.model_cost:.4f}s "
          f"vs flat shrink {eng.cost_flat(16):.4f}s")
    print(f"new master of legion 1: {topo.legion_of(victim + 1).master}")

    print("\nexpected repair cost vs cluster size (Eq. 1, P(master)=1/k):")
    print(f"{'s':>6} {'k*':>4} {'flat S(s)':>10} {'E[R_H]':>10} {'win':>6}")
    for s in (16, 64, 256, 1024, 4096):
        k = optimal_k_linear(s)
        flat = eng.cost_flat(s)
        hier = eng.expected_repair_cost(s, k)
        print(f"{s:6d} {k:4d} {flat:10.3f} {hier:10.3f} "
              f"{flat / hier:5.1f}x")


if __name__ == "__main__":
    main()
