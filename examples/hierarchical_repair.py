"""Anatomy of a hierarchical repair (paper §V, Fig. 3) — narrated.

Builds the paper's exact topology figure (16 processes, k=4), kills a
master, and prints every repair stage with its communicator, participants,
and S(x) model cost — then compares against the flat shrink, sweeps the
cluster size to show the crossover the paper derives in Eq. 2, and shows
the N-level generalization: the same master fault at depth 3 repairs a
bounded subtree instead of dragging in every master.

  PYTHONPATH=src python examples/hierarchical_repair.py
"""
from repro.core import LegioPolicy, ShrinkCostModel, ShrinkEngine
from repro.core.hierarchy import LegionTopology
from repro.core.policy import optimal_k_linear


def main() -> None:
    topo = LegionTopology.build(list(range(16)), 4)
    print("topology: 16 nodes, k=4")
    for lg in topo.legions:
        print(f"  legion {lg.index}: members {lg.members} "
              f"(master {lg.master}, POV {topo.pov(lg.index)})")

    eng = ShrinkEngine(LegioPolicy(), ShrinkCostModel(p=1.0))
    victim = topo.legions[1].master
    print(f"\nkilling node {victim} — master of legion 1. Repair plan:")
    report = eng.repair(topo, {victim})
    for i, step in enumerate(report.steps):
        print(f"  {i + 1}. {step.op:8s} on {step.comm:9s} "
              f"participants={list(step.participants)} "
              f"S(x) cost={step.cost_units:.4f}s")
    print(f"total model cost {report.model_cost:.4f}s "
          f"vs flat shrink {eng.cost_flat(16):.4f}s")
    print(f"new master of legion 1: {topo.legion_of(victim + 1).master}")

    print("\nexpected repair cost vs cluster size (Eq. 1, P(master)=1/k):")
    print(f"{'s':>6} {'k*':>4} {'flat S(s)':>10} {'E[R_H]':>10} {'win':>6}")
    for s in (16, 64, 256, 1024, 4096):
        k = optimal_k_linear(s)
        flat = eng.cost_flat(s)
        hier = eng.expected_repair_cost(s, k)
        print(f"{s:6d} {k:4d} {flat:10.3f} {hier:10.3f} "
              f"{flat / hier:5.1f}x")

    # -- the N-level generalization: scoped repair at depth 3 ---------------
    deep = LegionTopology.build(list(range(64)), 4, depth=3)
    victim = deep.legions[-1].master            # master of legion 15 only
    scope = deep.partition_scopes({victim})[0]
    print(f"\ndepth-3 topology (64 nodes, k=4): killing node {victim} "
          f"(a legion master)")
    print(f"  repair scope: {scope.summary()}")
    print(f"  comms touched: {list(scope.groups)}")
    print("  every node outside those comms keeps computing — at depth 2 "
          "the same fault\n  would shrink the 16-master global_comm; flat, "
          "all 63 survivors")


if __name__ == "__main__":
    main()
