"""Anatomy of fault-resilient serving (repro.serve) — narrated.

A 16-node cluster (k=4 → 4 legions) serves a streaming request campaign
under non-blocking substitution. Mid-campaign a worker and a legion master
die with batches in flight; the walkthrough prints, round by round, what
the serve subsystem does about it:

  * the RequestRouter shards arrivals across legions (least-loaded);
  * each legion drains micro-batches (LegioPolicy.serve_microbatch);
  * the dying nodes take their in-flight batches with them — the
    FaultPipeline agrees on the verdict and the ServeEngine's listener
    re-enqueues exactly those requests (front of the queue);
  * healthy legions keep dispatching in the same round — repair never
    barriers serving;
  * the dedup guard keeps redelivery invisible: every request id completes
    exactly once from the client's view.

  PYTHONPATH=src python examples/resilient_serving.py
"""
import numpy as np

from repro.core import FaultInjector, LegioPolicy, VirtualCluster
from repro.serve import Request, ServeEngine

N_NODES = 16
TOTAL_REQUESTS = 180
ARRIVALS_PER_ROUND = 48


def score(node: int, batch: list[Request], step: int) -> dict[int, float]:
    """The model stand-in: a deterministic per-request 'docking score'."""
    return {r.rid: float(np.sin(r.rid) * 100.0) for r in batch}


def main() -> None:
    policy = LegioPolicy(
        legion_size=4,
        serve_microbatch=3,
        recovery_mode="substitute_then_shrink",
        spare_fraction=0.25,                # 4 warm spares
        nonblocking_substitution=True,      # repair overlaps serving
    )
    injector = FaultInjector.at([(1, 5), (2, 0)])   # a worker, then a master
    cluster = VirtualCluster(N_NODES, policy=policy, injector=injector)
    engine = ServeEngine(cluster, score)

    print(f"[serve] {N_NODES} nodes, k=4 -> {cluster.topo.n_legions} legions, "
          f"masters {cluster.topo.masters}, "
          f"{len(cluster.spare_pool.available)} warm spares")

    submitted = 0
    round_idx = 0
    while submitted < TOTAL_REQUESTS or engine.pending:
        if submitted < TOTAL_REQUESTS:
            n = min(ARRIVALS_PER_ROUND, TOTAL_REQUESTS - submitted)
            engine.submit(n)
            submitted += n
        rep = engine.run_round()
        line = (f"  round {rep.step}: dispatched {sum(rep.dispatched.values())} "
                f"to {len(rep.dispatched)} nodes, completed {rep.completed_now}, "
                f"backlog {rep.backlog}")
        if rep.requeued_now:
            line += f", RE-ENQUEUED {rep.requeued_now} in-flight"
        for a in rep.actions:
            line += (f"\n           fault: verdict {list(a.verdict)} "
                     f"via {[s.value for s in a.sources]} -> "
                     f"{a.strategy} ({a.report.mode if a.report else '-'})")
        if rep.expanded:
            line += f"\n           splice landed: {list(rep.expanded)}"
        print(line)
        round_idx += 1

    m = engine.metrics.summary(round_idx)
    print(f"\n[serve] campaign done in {round_idx} rounds: "
          f"{m['completed']}/{TOTAL_REQUESTS} completed, "
          f"{m['requeues']} redeliveries, "
          f"{m['duplicates_suppressed']} duplicates suppressed")
    print(f"[serve] latency p50={m['p50_latency_rounds']:.0f} "
          f"p99={m['p99_latency_rounds']:.0f} rounds; "
          f"goodput {m['goodput_rps']:.1f} req/round; "
          f"survivors {len(cluster.live_nodes)}/{N_NODES}")

    # the guarantees, asserted: every id completed (at-least-once
    # redelivery), each exactly once (write-once dedup guard)
    assert sorted(engine.completed) == list(range(TOTAL_REQUESTS))
    rids = [r.rid for r in engine.metrics.completions]
    assert len(rids) == len(set(rids)) == TOTAL_REQUESTS
    fault_legions = {cluster.topo.home[0], cluster.topo.home[5]}
    healthy = [lg.index for lg in cluster.topo.legions
               if lg.members and lg.index not in fault_legions]
    stalls = sum(engine.metrics.stalled_rounds(lg, 1, 2) for lg in healthy)
    assert stalls == 0, "healthy legions must keep dispatching during repair"
    print(f"[serve] healthy legions {healthy} never stalled during the "
          f"repair rounds (0 zero-dispatch rounds in the trace)")


if __name__ == "__main__":
    main()
