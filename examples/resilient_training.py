"""End-to-end driver: train a llama through node failures.

A small llama (~13M params by default; ``--full`` scales to ~100M,
``--tiny`` shrinks to CI size) trains on the counter-based Markov stream
while the virtual cluster loses two nodes — one mid-warmup, one legion
master. Checkpoints are written per-legion; at the end the script
demonstrates restart-only-failed: a replacement node restores *only* the
dead member's shard and the loss curve continues where it left off.

The default is sized to finish in well under a minute on a laptop CPU
(every file under examples/ is held to that budget — see
tests/test_examples.py); ``--full`` restores the original ~100M/300-step
campaign for overnight-scale runs.

  PYTHONPATH=src python examples/resilient_training.py           # ~13M, fast
  PYTHONPATH=src python examples/resilient_training.py --full    # ~100M
  PYTHONPATH=src python examples/resilient_training.py --tiny    # CI-sized
"""
import argparse
import tempfile

import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import (
    FaultInjector,
    LegionCheckpointer,
    LegioPolicy,
    ResilientTrainer,
    VirtualCluster,
)

MODEL_100M = ModelConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
    attn_block_q=128, attn_block_k=128, xent_chunk=128, remat="none",
)

MODEL_TINY = MODEL_100M.replace(
    name="llama-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512)

# default: big enough to show a real loss curve, small enough that the
# whole walkthrough (train + 2 repairs + checkpoint restore) stays under
# the examples/ ~60 s budget on CPU
MODEL_SMALL = MODEL_100M.replace(
    name="llama-5m", n_layers=3, d_model=256, n_heads=4, n_kv_heads=2,
    head_dim=64, d_ff=768, vocab_size=4096)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI-sized model")
    ap.add_argument("--full", action="store_true",
                    help="the original ~100M / 300-step campaign")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    cfg = (MODEL_TINY if args.tiny
           else MODEL_100M if args.full else MODEL_SMALL)
    steps = args.steps or (60 if args.tiny else 300 if args.full else 40)
    seq_len = 64 if args.tiny else 256 if args.full else 96

    tc = TrainConfig(learning_rate=3e-3, total_steps=steps,
                     warmup_steps=max(steps // 10, 1),
                     checkpoint_every=max(steps // 4, 1))
    injector = FaultInjector.at([
        (steps // 6, 5),        # a worker dies early
        (steps // 2, 0),        # a legion master dies mid-run
    ])
    cluster = VirtualCluster(
        8, policy=LegioPolicy(legion_size=4), injector=injector)

    ckpt_dir = tempfile.mkdtemp(prefix="legio_ckpt_")
    ckpt = LegionCheckpointer(ckpt_dir)
    trainer = ResilientTrainer(cfg, tc, cluster, per_shard_batch=2,
                               seq_len=seq_len, checkpointer=ckpt)
    n_params = sum(x.size for x in _leaves(trainer.params))
    print(f"[example] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{steps} steps, 8 nodes (k=4), checkpoints -> {ckpt_dir}")

    for _ in range(steps):
        r = trainer.run_step()
        if r.step % max(steps // 15, 1) == 0 or r.repair:
            extra = f"  {r.repair.summary()}" if r.repair else ""
            print(f"  step {r.step:4d}  loss {r.loss:.4f}  "
                  f"shards {r.active_shards}{extra}")

    losses = [r.loss for r in trainer.history]
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"through 2 failures; survivors={len(cluster.live_nodes)}/8")
    assert losses[-1] < losses[0]

    # --- restart-only-failed (§VII): bring a replacement for node 5 back ---
    # Per-member files are self-contained; data-parallel state is replicated,
    # so a replacement restores from ANY single member file (here: the master
    # of node 5's old legion) and regenerates node 5's shards via the
    # counter-based pipeline. No survivor is interrupted.
    ckpt.wait()
    legion = cluster.topo.home.get(5, 1)
    donor = cluster.topo.legion_of(
        min(cluster.live_nodes)).master if cluster.live_nodes else 0
    donor_legion = cluster.topo.home[donor]
    state = ckpt.restore_failed_member(donor_legion, donor)
    restored_step = int(np.asarray(state["meta"]["step"]))
    print(f"[example] replacement for node 5 (legion {legion}) restored from "
          f"member file of node {donor} at step {restored_step} — exactly one "
          f"file read, no surviving member interrupted")
    ckpt.close()


def _leaves(tree):
    import jax
    return jax.tree.leaves(tree)


if __name__ == "__main__":
    main()
