"""End-to-end driver: train a ~100M-param llama through node failures.

A 28M..100M-parameter model (flag-selectable) trains for a few hundred steps
on the counter-based Markov stream while the virtual cluster loses three
nodes — one mid-warmup, one master, and one straggler that gets soft-failed.
Checkpoints are written per-legion; at the end the script demonstrates
restart-only-failed: a replacement node restores *only* the dead member's
shard and the loss curve continues where it left off.

  PYTHONPATH=src python examples/resilient_training.py           # ~100M
  PYTHONPATH=src python examples/resilient_training.py --tiny    # CI-sized
"""
import argparse
import tempfile

import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import (
    FaultInjector,
    LegionCheckpointer,
    LegioPolicy,
    ResilientTrainer,
    VirtualCluster,
)

MODEL_100M = ModelConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
    attn_block_q=128, attn_block_k=128, xent_chunk=128, remat="none",
)

MODEL_TINY = MODEL_100M.replace(
    name="llama-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI-sized model")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    cfg = MODEL_TINY if args.tiny else MODEL_100M
    steps = args.steps or (60 if args.tiny else 300)
    seq_len = 64 if args.tiny else 256

    tc = TrainConfig(learning_rate=3e-3, total_steps=steps,
                     warmup_steps=max(steps // 10, 1),
                     checkpoint_every=max(steps // 4, 1))
    injector = FaultInjector.at([
        (steps // 6, 5),        # a worker dies early
        (steps // 2, 0),        # a legion master dies mid-run
    ])
    cluster = VirtualCluster(
        8, policy=LegioPolicy(legion_size=4), injector=injector)

    ckpt_dir = tempfile.mkdtemp(prefix="legio_ckpt_")
    ckpt = LegionCheckpointer(ckpt_dir)
    trainer = ResilientTrainer(cfg, tc, cluster, per_shard_batch=2,
                               seq_len=seq_len, checkpointer=ckpt)
    n_params = sum(x.size for x in _leaves(trainer.params))
    print(f"[example] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{steps} steps, 8 nodes (k=4), checkpoints -> {ckpt_dir}")

    for _ in range(steps):
        r = trainer.run_step()
        if r.step % max(steps // 15, 1) == 0 or r.repair:
            extra = f"  {r.repair.summary()}" if r.repair else ""
            print(f"  step {r.step:4d}  loss {r.loss:.4f}  "
                  f"shards {r.active_shards}{extra}")

    losses = [r.loss for r in trainer.history]
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"through 2 failures; survivors={len(cluster.live_nodes)}/8")
    assert losses[-1] < losses[0]

    # --- restart-only-failed (§VII): bring a replacement for node 5 back ---
    # Per-member files are self-contained; data-parallel state is replicated,
    # so a replacement restores from ANY single member file (here: the master
    # of node 5's old legion) and regenerates node 5's shards via the
    # counter-based pipeline. No survivor is interrupted.
    ckpt.wait()
    legion = cluster.topo.home.get(5, 1)
    donor = cluster.topo.legion_of(
        min(cluster.live_nodes)).master if cluster.live_nodes else 0
    donor_legion = cluster.topo.home[donor]
    state = ckpt.restore_failed_member(donor_legion, donor)
    restored_step = int(np.asarray(state["meta"]["step"]))
    print(f"[example] replacement for node 5 (legion {legion}) restored from "
          f"member file of node {donor} at step {restored_step} — exactly one "
          f"file read, no surviving member interrupted")
    ckpt.close()


def _leaves(tree):
    import jax
    return jax.tree.leaves(tree)


if __name__ == "__main__":
    main()
