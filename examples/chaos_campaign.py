"""Run a chaos campaign against the recovery stack — narrated.

Picks one or more named fault-model presets (the "correlated-failure
zoo" of :mod:`repro.core.faultmodel`), replays each against both a
training and a serving workload under the selected recovery modes, and
prints the invariant checks the harness applies after every drain:
exactly-once serving accounting, message-ledger conservation, topology
coherence, and the per-scenario guarantees (a rack resolves in one
drain, a fenced partition never double-repairs, a flapping node stays
out, ...).

  PYTHONPATH=src python examples/chaos_campaign.py
  PYTHONPATH=src python examples/chaos_campaign.py \
      --preset rack_outage --preset transient_flap --recovery substitute

Exits nonzero if any invariant fails — CI runs the two-preset form
above as a smoke test of the whole fault pipeline.
"""
import argparse
import sys

from repro.core import ChaosHarness, FaultModel
from repro.core.chaos import RECOVERIES


def parse_args(argv: list[str] | None = None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--preset", action="append", dest="presets",
                    choices=FaultModel.SCENARIOS, metavar="NAME",
                    help="scenario preset to run (repeatable; default: all "
                         f"of {', '.join(FaultModel.SCENARIOS)})")
    ap.add_argument("--recovery", action="append", dest="recoveries",
                    choices=RECOVERIES, metavar="MODE",
                    help="recovery mode (repeatable; default: shrink)")
    ap.add_argument("--nodes", type=int, default=64,
                    help="cluster size (default 64 — auto-builds a "
                         "depth-3 topology, so rack presets have real "
                         "subtrees to kill)")
    ap.add_argument("--seed", type=int, default=0,
                    help="campaign seed (same seed -> identical events)")
    return ap.parse_args(argv)


def main(argv: list[str] | None = None) -> int:
    args = parse_args(argv)
    presets = tuple(args.presets or FaultModel.SCENARIOS)
    recoveries = tuple(args.recoveries or ("shrink",))

    harness = ChaosHarness(seed=args.seed)
    print(f"chaos campaign: n={args.nodes} seed={args.seed}")
    print(f"  presets:    {', '.join(presets)}")
    print(f"  recoveries: {', '.join(recoveries)}\n")

    failures = 0
    for preset in presets:
        campaign = harness.model.campaign(preset, args.nodes)
        print(f"== {preset} ==")
        print(f"   {campaign.summary()}")
        for ev in campaign.events:
            print(f"   step {ev.step:2d}: {ev.action.name.lower():12s} "
                  f"nodes={list(ev.nodes)}"
                  + (f" observers={len(ev.observers)}" if ev.observers
                     else "")
                  + (f" factor={ev.factor}" if ev.factor != 1.0 else ""))
        for recovery in recoveries:
            for report in (harness.run_train(preset, args.nodes,
                                             recovery=recovery),
                           harness.run_serve(preset, args.nodes,
                                             recovery=recovery)):
                print(f"   {report.summary()}")
                for chk in report.failures:
                    failures += 1
                    print(f"     FAIL {chk.name}: {chk.detail}")
        print()

    if failures:
        print(f"{failures} invariant check(s) FAILED")
        return 1
    print("all invariants held across every preset x recovery x workload")
    return 0


if __name__ == "__main__":
    sys.exit(main())
