"""Transparency, demonstrated: an unmodified "plain MPI" loop survives faults.

The paper's claim is that Legio removes *any* integration effort — the app
is written as if nothing ever fails, and the interposition layer behind the
MPI calls repairs the communicator mid-call. The loop below is exactly that
program shape: init a session, compute a local value per rank, allreduce,
repeat. There is **zero fault-handling code in the loop body** — no
try/except, no topology inspection, no repair calls — yet three nodes
(including a legion master) die mid-run and every allreduce completes with
the survivors' exact sum. A point-to-point ring exchange rides along to
show the fault-aware non-collective layer: the ring re-closes around the
dead nodes without a single special case in the app.

  PYTHONPATH=src python examples/transparent_mpi.py
"""
import numpy as np

from repro.core import FaultInjector, LegioPolicy
from repro.mpi import Session

STEPS = 10


def local_work(rank: int, step: int) -> np.ndarray:
    """Any embarrassingly parallel kernel; here: rank's share of a sum."""
    return np.array([float(rank + 1), 1.0])


def main() -> None:
    # --- the ONLY Legio-aware lines: choosing the cluster + fault script ---
    session = Session(
        16,
        policy=LegioPolicy(legion_size=4),
        injector=FaultInjector.at([(2, 9), (5, 4), (7, 11)]),  # 4 is a master
    )

    # --- from here on: a plain MPI program -------------------------------
    comm = session.world
    print(f"world size {comm.size}")
    for step in range(STEPS):
        session.advance(step)                     # MPI apps: time passing
        contributions = {
            rank: local_work(rank, step)
            for rank in session.cluster.live_nodes  # ranks that run code
        }
        res = comm.allreduce(contributions)
        total, count = res.data[comm.members[0]]
        print(f"step {step}: sum={total:.0f} over {count:.0f} ranks "
              f"(world size {comm.size})")

    # p2p epilogue: each rank passes a token to its ring successor — the
    # ring is over whatever members survived, no app-side bookkeeping
    members = comm.members
    for i, rank in enumerate(members):
        comm.send(rank, members[(i + 1) % len(members)], f"token-from-{rank}")
    handed = sum(
        comm.probe(rank, members[i - 1]) and
        comm.recv(rank, members[i - 1]).startswith("token")
        for i, rank in enumerate(members)
    )
    print(f"\nring exchange: {handed}/{len(members)} tokens delivered, "
          f"ledger conserved={comm.ledger.conserved()}")

    survivors = comm.size
    print(f"final: {survivors}/16 nodes survive; "
          f"{comm.stats.repair_rounds} faults repaired inside MPI calls; "
          f"loop body contains zero fault-handling code")
    assert survivors == 13 and handed == 13
    session.finalize()


if __name__ == "__main__":
    main()
