"""Elastic spare re-spawn — healing a spare-exhausted campaign.

PR 1 left a gap (ROADMAP): the SparePool is provisioned once at cluster
start, so a campaign with more faults than spares ends up degraded forever
under substitute_then_shrink. The SpareProvisioner closes it — the
MPI_Comm_spawn analogue:

  * when the warm pool drains below ``spare_refill_watermark``, replacement
    spares are scheduled (acquiring + booting a node takes
    ``spare_provision_delay_steps`` steps — never free);
  * ``spare_churn_cap`` bounds total re-spawns over the campaign;
  * delivered spares feed back through the SparePool, and slots that had to
    be shrunk during exhaustion (the backlog) heal through the same
    pending-splice path as a non-blocking substitution — assignment
    finality and the lowest-rank master rule hold by construction.

Run:
  PYTHONPATH=src python examples/elastic_respawn.py
"""
import numpy as np

from repro.core import FaultInjector, LegioExecutor, LegioPolicy, VirtualCluster


def work(node, shard, step):
    return np.ones(1) * (shard + 1)


def main() -> None:
    n = 16
    policy = LegioPolicy(
        legion_size=4,
        recovery_mode="substitute_then_shrink",
        spare_nodes=2,                   # provisioned once at start
        spare_refill_watermark=2,        # re-spawn when the pool dips below 2
        spare_provision_delay_steps=2,   # node acquisition + boot
        spare_churn_cap=8,               # never spawn more than 8 replacements
    )
    # 4 simultaneous faults against 2 warm spares: exhaustion by design
    injector = FaultInjector.at([(2, 1), (2, 2), (2, 5), (2, 9)])
    cl = VirtualCluster(n, policy=policy, injector=injector)
    ex = LegioExecutor(cl, work)

    print(f"--- {n} nodes, {len(cl.spare_pool)} warm spares, "
          f"4 faults due at step 2 ---")
    for step in range(12):
        r = ex.run_step()
        notes = []
        if r.failed_now:
            notes.append(f"failed={list(r.failed_now)}")
        if r.repair:
            notes.append(f"repair={r.repair.mode} "
                         f"unfilled={list(r.repair.unfilled)}")
        if r.respawned:
            notes.append(f"re-spawned spares {list(r.respawned)} delivered")
        if r.expanded:
            notes.append(f"healed slots {list(r.expanded)}")
        state = (f"step {r.step}: {len(r.results)}/{n} computing, "
                 f"pool={cl.spare_pool.available or '[]'}")
        print(state + ("   " + "; ".join(notes) if notes else ""))

    print(f"--- campaign over: topology {cl.topo.size}/{n} nodes, "
          f"{cl.plan.active_shards}/{n} shards/step, "
          f"{cl.provisioner.spawned} spares re-spawned "
          f"(cap {policy.spare_churn_cap}) ---")
    assert cl.topo.size == n and cl.plan.active_shards == n
    print("full capacity restored — the exhausted campaign healed itself")


if __name__ == "__main__":
    main()
