"""Warm spare pool walkthrough — shrink vs substitute vs non-blocking.

Runs the same 16-node / one-fault scenario under the three recovery modes
and narrates what each does at the repair seam:

  shrink                — the paper's discard-and-continue: node 5's shard
                          is gone, every later step computes 15/16 of the
                          batch;
  substitute            — a warm spare from the pool splices into node 5's
                          legion slot during the repair; the next step is
                          back at 16/16;
  non-blocking          — the fault step repairs by shrink (cheap), the
                          spare warms up for one step, then the topology
                          re-expands at the next boundary — repair overlaps
                          useful work.

Then it exhausts the pool to show the substitute_then_shrink fallback.

  PYTHONPATH=src python examples/spare_pool.py
"""
import numpy as np

from repro.core import (
    FaultInjector,
    LegioExecutor,
    LegioPolicy,
    VirtualCluster,
)

N, VICTIM, FAULT_STEP, STEPS = 16, 5, 2, 6
FULL = sum(range(1, N + 1))


def work(node, shard, step):
    return np.ones(1) * (shard + 1)


def narrate(mode: str, policy: LegioPolicy) -> None:
    cl = VirtualCluster(N, policy=policy,
                        injector=FaultInjector.at([(FAULT_STEP, VICTIM)]))
    ex = LegioExecutor(cl, work)
    print(f"\n--- recovery_mode={mode} "
          f"(pool: {cl.spare_pool.available or 'none'}) ---")
    for _ in range(STEPS):
        r = ex.run_step()
        line = (f"step {r.step}: reduce={float(r.reduced[0]):6.1f}/{FULL} "
                f"shards={cl.plan.active_shards:2d}/{N}")
        if r.repair is not None:
            line += (f"  REPAIR {r.repair.mode}: "
                     f"survivors={r.repair.survivors}"
                     + (f" spliced={list(r.repair.substitutions)}"
                        if r.repair.substitutions else "")
                     + f" cost={r.repair.model_cost:.3f}s")
        if r.expanded:
            line += f"  RE-EXPANDED {list(r.expanded)} (warmup done)"
        print(line)
    print(f"final: {cl.topo.size} nodes, "
          f"{len(cl.spare_pool)} spare(s) left, "
          f"total repair cost {sum(rep.model_cost for rep in cl.repairs):.3f}s")


def main() -> None:
    print(f"{N}-node cluster, node {VICTIM} dies at step {FAULT_STEP}")

    narrate("shrink", LegioPolicy(legion_size=4))
    narrate("substitute", LegioPolicy(
        legion_size=4, recovery_mode="substitute", spare_fraction=0.25))
    narrate("substitute (non-blocking)", LegioPolicy(
        legion_size=4, recovery_mode="substitute_then_shrink",
        nonblocking_substitution=True, spare_warmup_steps=1,
        spare_fraction=0.25))

    # pool exhaustion: two faults, one spare — second slot shrinks
    print("\n--- substitute_then_shrink with an undersized pool ---")
    cl = VirtualCluster(
        N,
        policy=LegioPolicy(legion_size=4,
                           recovery_mode="substitute_then_shrink",
                           spare_nodes=1),
        injector=FaultInjector.at([(1, 1), (3, 2)]))
    ex = LegioExecutor(cl, work)
    for r in ex.run(5):
        if r.repair is not None:
            print(f"step {r.step}: {r.repair.summary()}")
    print(f"final: {cl.topo.size}/{N} nodes — first fault substituted, "
          f"second shrunk (pool exhausted); the run never stopped")


if __name__ == "__main__":
    main()
