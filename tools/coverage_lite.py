"""Dependency-free statement coverage for environments without coverage.py.

A pytest plugin (``-p tools.coverage_lite``) that traces statement-line
execution under ``src/repro`` with :func:`sys.settrace` and scores it
against an AST-derived denominator (every statement's first line, the same
universe coverage.py counts). It exists so ``make coverage`` degrades
gracefully: CI installs pytest-cov and uses the real thing; a container
that cannot install anything still gets an enforceable number from the
standard library alone.

  PYTHONPATH=src python -m pytest -q -p tools.coverage_lite
  COVLITE_MIN=55 PYTHONPATH=src python -m pytest -q -p tools.coverage_lite

With ``COVLITE_MIN`` set, total coverage below that percentage fails the
run (the ``--cov-fail-under`` analogue). Accuracy caveats vs coverage.py:
no branch coverage, and lines only reachable through C-level callbacks may
be missed — the pinned floor should carry a small margin.
"""
from __future__ import annotations

import ast
import os
import pathlib
import sys
import threading

SRC_ROOT = str(pathlib.Path(__file__).resolve().parent.parent / "src"
               / "repro") + os.sep

_executed: dict[str, set[int]] = {}
# co_filename can be relative (PYTHONPATH=src) or carry ".." segments
# (conftest's sys.path insert) — canonicalize once per distinct spelling
_canon: dict[str, "str | None"] = {}


def _canonical(filename: str) -> "str | None":
    try:
        return _canon[filename]
    except KeyError:
        absf = os.path.normpath(os.path.abspath(filename))
        out = absf if absf.startswith(SRC_ROOT) else None
        _canon[filename] = out
        return out


def _trace(frame, event, arg):
    canon = _canonical(frame.f_code.co_filename)
    if canon is None:
        return None                      # never line-trace foreign frames
    if event == "line":
        _executed.setdefault(canon, set()).add(frame.f_lineno)
    return _trace


def _statement_lines(path: pathlib.Path) -> set[int]:
    """First line of every statement — the measurable universe."""
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return set()
    lines: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.stmt):
            # decorated defs report the decorator's line; the body line is
            # what actually executes
            lineno = node.lineno
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)) and node.decorator_list:
                lineno = node.decorator_list[0].lineno
            lines.add(lineno)
    return lines


def pytest_configure(config):
    sys.settrace(_trace)
    threading.settrace(_trace)


def pytest_unconfigure(config):
    sys.settrace(None)
    threading.settrace(None)


def _tally():
    root = pathlib.Path(SRC_ROOT)
    rows = []
    total_stmts = total_hit = 0
    for path in sorted(root.rglob("*.py")):
        stmts = _statement_lines(path)
        if not stmts:
            continue
        hit = _executed.get(str(path), set()) & stmts
        total_stmts += len(stmts)
        total_hit += len(hit)
        rows.append((str(path.relative_to(root.parent)),
                     len(stmts), len(hit)))
    pct = 100.0 * total_hit / total_stmts if total_stmts else 100.0
    return rows, total_stmts, total_hit, pct


def pytest_terminal_summary(terminalreporter):
    tr = terminalreporter
    rows, total_stmts, total_hit, pct = _tally()
    tr.write_sep("-", "coverage-lite (statement, src/repro)")
    for name, stmts, hit in rows:
        tr.write_line(f"{name:<52} {hit:>5}/{stmts:<5} "
                      f"{100.0 * hit / stmts:6.1f}%")
    tr.write_line(f"{'TOTAL':<52} {total_hit:>5}/{total_stmts:<5} "
                  f"{pct:6.1f}%")
    floor = os.environ.get("COVLITE_MIN")
    if floor is not None and pct < float(floor):
        tr.write_line(f"coverage-lite: {pct:.1f}% is below the "
                      f"COVLITE_MIN={floor}% floor", red=True)


def pytest_sessionfinish(session, exitstatus):
    # stop tracing before teardown noise; enforce the floor by mutating
    # session.exitstatus (pytest returns it after this hook runs)
    sys.settrace(None)
    floor = os.environ.get("COVLITE_MIN")
    if floor is None:
        return
    _, _, _, pct = _tally()
    if pct < float(floor) and session.exitstatus == 0:
        session.exitstatus = 1
