"""Docs link-checker: dead relative links in docs/ or README fail the build.

Scans markdown files for inline links and validates every *relative* target
(path exists, rooted at the linking file's directory). External URLs and
in-page anchors are skipped — this is a structure check, not a crawler.

  python tools/check_links.py            # README.md + docs/**/*.md
  python tools/check_links.py FILE...    # explicit file list
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
# inline markdown links, excluding images; badge-style nested [![...]] links
# are caught by the inner [...]() too
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def default_files() -> list[pathlib.Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_file(md: pathlib.Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        # "../../actions/..." style badge links point above the repo on
        # purpose (GitHub resolves them server-side) — out of scope
        resolved = (md.parent / path).resolve()
        if not resolved.is_relative_to(REPO):
            continue
        if not resolved.exists():
            line = text[:m.start()].count("\n") + 1
            errors.append(f"{md.relative_to(REPO)}:{line}: dead link "
                          f"-> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = ([pathlib.Path(a).resolve() for a in argv]
             if argv else default_files())
    errors = []
    for md in files:
        errors += check_file(md)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"[docs] {len(files)} file(s) checked, {len(errors)} dead link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
